"""Federated daemon mesh tests: rendezvous ownership, peer read-through,
N-way replication over disjoint cache roots, queue-job adoption, and the
degrade-to-local paths (drain, partition, key skew).

The structural invariant everywhere: the mesh may only change *where* a
cell is computed and how many copies exist — records are always
bit-identical to the in-process backend, and any peer failure degrades
to local simulation."""

import contextlib
import socket
import threading

import pytest

from repro.core.warpsim import api, machines
from repro.core.warpsim import mesh as mesh_mod
from repro.core.warpsim.api import (
    QueueBackend, ServiceBackend, Session, Study,
)
from repro.core.warpsim.faults import FaultPlan, ServiceError
from repro.core.warpsim.mesh import MeshConfig, rendezvous_ranking
from repro.core.warpsim.service import (
    ResilientClient, SweepClient, SweepService, serve,
)
from repro.core.warpsim.sweep import cell_key
from repro.core.warpsim.work_queue import _worker_urls, run_worker

SMALL = dict(benches=("BFS", "DYN"), n_threads=128)


def _study(**kw):
    base = dict(machines={"ws8": machines.baseline(8),
                          "SW+": machines.sw_plus()}, **SMALL)
    base.update(kw)
    return Study(**base)


def _noop_sleep(_seconds):
    pass


def _dead_url():
    """A URL that is guaranteed to refuse connections right now."""
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return f"http://127.0.0.1:{port}"


class _daemon:
    """Context manager: serve `svc` on an ephemeral port, yield its URL."""

    def __init__(self, svc):
        self.svc = svc

    def __enter__(self):
        self.httpd = serve(self.svc)
        threading.Thread(target=self.httpd.serve_forever,
                         daemon=True).start()
        return "http://%s:%d" % self.httpd.server_address[:2]

    def __exit__(self, *exc):
        self.httpd.shutdown()
        self.httpd.server_close()


@contextlib.contextmanager
def mesh_trio(tmp_path, replication=2, fault_plans=(None, None, None)):
    """Three live daemons over DISJOINT cache roots, meshed together.

    Yields ``(services, urls)``. The self URL is only known after bind,
    so services are constructed with ``mesh=False`` and join via
    ``configure_mesh`` — the same dance the CLI does for ``--port 0``.
    """
    svcs = [SweepService(str(tmp_path / f"root{i}"), persist_traces=False,
                         mesh=False, fault_plan=fault_plans[i])
            for i in range(3)]
    with contextlib.ExitStack() as stack:
        urls = [stack.enter_context(_daemon(s)) for s in svcs]
        for svc, url in zip(svcs, urls):
            svc.configure_mesh(
                MeshConfig.build(url, urls, replication=replication))
        yield svcs, urls


def _fleet_client(urls):
    return ResilientClient(urls, max_retries=8, breaker_threshold=99,
                           seed=0, sleep=_noop_sleep, timeout=120.0)


def _total_simulated(svcs):
    return sum(s.counters["simulated"] for s in svcs)


# ---------------------------------------------------- rendezvous hashing

def test_rendezvous_ranking_deterministic_and_monotone():
    """Same inputs -> same ranking, and removing one member never
    reorders the survivors (the property failover leans on: the ranking
    minus a dead owner IS the replica walk order)."""
    members = [f"http://node{i}:8321" for i in range(5)]
    keys = [f"key-{i}" for i in range(50)]
    for key in keys:
        full = rendezvous_ranking(key, members)
        assert full == rendezvous_ranking(key, list(reversed(members)))
        for gone in members:
            survivors = rendezvous_ranking(
                key, [m for m in members if m != gone])
            assert survivors == [m for m in full if m != gone]
    # Ownership spreads: with 50 keys over 5 members, no member owns
    # everything (sha256 would have to be wildly biased).
    owners = {rendezvous_ranking(k, members)[0] for k in keys}
    assert len(owners) > 1


def test_mesh_config_build_normalizes():
    cfg = MeshConfig.build(
        "http://a:1/", ["http://b:2/", "http://a:1", " http://b:2 ",
                        "http://c:3", ""], replication=3)
    assert cfg.self_url == "http://a:1"
    assert cfg.peers == ("http://b:2", "http://c:3")
    assert cfg.members == ("http://a:1", "http://b:2", "http://c:3")
    assert cfg.replication == 3
    # Replication beyond membership is capped by targets(), not rejected.
    big = MeshConfig.build("http://a:1", ["http://b:2"], replication=5)
    assert len(big.targets("anything")) == 2
    with pytest.raises(ValueError):
        MeshConfig.build("http://a:1", [], replication=0)
    with pytest.raises(ValueError):
        MeshConfig(self_url="", peers=())


def test_mesh_config_ranking_roles():
    cfg = MeshConfig.build("http://a:1", ["http://b:2", "http://c:3"],
                           replication=2)
    for key in (f"k{i}" for i in range(20)):
        ranking = cfg.ranking(key)
        assert cfg.owner(key) == ranking[0]
        assert cfg.targets(key) == ranking[:2]
        assert cfg.self_url not in cfg.replica_targets(key)
        order = cfg.fetch_order(key)
        if cfg.owner(key) == cfg.self_url:
            assert order == []          # we own it: simulate, don't ask
        else:
            assert order[0] == cfg.owner(key)
            assert cfg.self_url not in order


def test_mesh_config_from_env(monkeypatch):
    monkeypatch.delenv(mesh_mod.ENV_PEERS, raising=False)
    monkeypatch.delenv(mesh_mod.ENV_SELF, raising=False)
    assert MeshConfig.from_env() is None
    monkeypatch.setenv(mesh_mod.ENV_PEERS, "http://a:1, http://b:2")
    with pytest.raises(ValueError):    # peers without a self URL: loud
        MeshConfig.from_env()
    monkeypatch.setenv(mesh_mod.ENV_SELF, "http://a:1")
    monkeypatch.setenv(mesh_mod.ENV_REPLICATION, "3")
    cfg = MeshConfig.from_env()
    assert cfg.self_url == "http://a:1"
    assert cfg.peers == ("http://b:2",)
    assert cfg.replication == 3


def test_sweep_service_reads_mesh_env(tmp_path, monkeypatch):
    monkeypatch.setenv(mesh_mod.ENV_PEERS, "http://a:1,http://b:2")
    monkeypatch.setenv(mesh_mod.ENV_SELF, "http://a:1")
    svc = SweepService(str(tmp_path / "env"), persist_traces=False)
    assert svc.mesh is not None and svc.mesh.peers == ("http://b:2",)
    # mesh=False suppresses the env path (the CLI's pre-bind state).
    off = SweepService(str(tmp_path / "off"), persist_traces=False,
                       mesh=False)
    assert off.mesh is None
    assert off.mesh_stats() == {"enabled": False}


# ------------------------------------------- read-through + replication

def _pick_cell(mesh_cfg, owner_url):
    """A (bench, cfg, seed) whose rendezvous owner is `owner_url`."""
    cfg = machines.baseline(8)
    for seed in range(64):
        key = cell_key("BFS", cfg, 128, seed)
        if mesh_cfg.owner(key) == owner_url:
            return "BFS", cfg, seed, key
    raise AssertionError("no cell owned by that daemon in 64 seeds")


def test_local_miss_reads_through_to_owner(tmp_path):
    """A non-owner's cold /cell is served by the owner: exactly one
    simulation fleet-wide, source "peer" at the requester, and the
    result is adopted into the requester's own (disjoint) cache."""
    with mesh_trio(tmp_path) as (svcs, urls):
        bench, cfg, seed, key = _pick_cell(svcs[0].mesh, urls[0])
        requester = next(s for s, u in zip(svcs, urls) if u != urls[0])
        res, src = requester.cell_with_source(bench, cfg, 128, seed)
        assert src == "peer"
        assert svcs[0].counters["simulated"] == 1
        assert svcs[0].counters["peer_serves"] == 1
        assert requester.counters["simulated"] == 0
        assert requester.counters["peer_hits"] == 1
        assert requester.cache.contains(key)    # adopted locally
        # Owner asked directly: plain simulation, no forward loop.
        assert _total_simulated(svcs) == 1
        ref = api.Session().run(
            Study(machines={"ws8": cfg}, benches=(bench,), n_threads=128,
                  seeds=(seed,)))
        assert res == ref.records[0].result


def test_owner_simulation_replicates_to_successors(tmp_path):
    """The owner's freshly simulated cell lands on exactly the
    replication-1 successors — and NOT on the remaining member."""
    with mesh_trio(tmp_path, replication=2) as (svcs, urls):
        bench, cfg, seed, key = _pick_cell(svcs[0].mesh, urls[0])
        svcs[0].cell(bench, cfg, 128, seed)
        targets = svcs[0].mesh.targets(key)
        assert targets[0] == urls[0] and len(targets) == 2
        for svc, url in zip(svcs, urls):
            if url == urls[0]:
                continue
            assert svc.cache.contains(key) == (url in targets)
        assert svcs[0].counters["replicas_sent"] == 1
        replica = next(s for s, u in zip(svcs, urls)
                       if u in targets and u != urls[0])
        assert replica.counters["replicas_adopted"] == 1


def test_mesh_study_disjoint_roots_bit_identical(tmp_path):
    """The tentpole contract, fault-free half: a study through a 3-daemon
    mesh over disjoint roots simulates every cell exactly once
    fleet-wide, returns records bit-identical to in-process, and a warm
    re-study via a *different* daemon simulates nothing new (read-through
    + replicas serve it all)."""
    study = _study(seeds=(0, 1))
    cells = len(study.cells())
    reference = api.Session().run(study)
    with mesh_trio(tmp_path) as (svcs, urls):
        res = Session(backend=ServiceBackend(
            client=_fleet_client(urls))).run(study)
        assert res.records == reference.records
        assert _total_simulated(svcs) == cells
        assert res.stats["simulated"] + res.stats["peer_hits"] \
            + res.stats["cache_hits"] + res.stats["dedup_waits"] == cells
        # Warm pass pointed at one *other* daemon only: zero new sims.
        warm = SweepClient(urls[2], timeout=120.0).study(study)
        assert warm.records == reference.records
        assert _total_simulated(svcs) == cells
        assert warm.stats["simulated"] == 0


def test_owner_killed_mid_study_bounded_duplicates(tmp_path):
    """The tentpole acceptance scenario: the daemon serving the /study
    dies after K simulated cells; the ResilientClient fails over, the
    successor re-serves from replicas + read-through, records stay
    bit-identical, and duplicate simulations are bounded by the
    replication factor. No raw urllib exception escapes Session.run.

    Ownership depends on the daemons' ephemeral-port URLs, so the victim
    is picked after bind: the daemon owning the most of the study's
    cells (pigeonhole over 8 cells / 3 members: >= 3) serves the study
    and is killed on its 3rd simulated cell — the kill always fires."""
    study = _study(seeds=(0, 1))
    spec = study.to_spec()
    cells = len(spec.cells())
    reference = api.Session().run(study)
    replication = 2
    with mesh_trio(tmp_path, replication=replication) as (svcs, urls):
        owned = {u: 0 for u in urls}
        for _m, cfg, bench, n_threads, seed in spec.cells():
            owned[svcs[0].mesh.owner(
                cell_key(bench, cfg, n_threads, seed))] += 1
        victim = max(urls, key=lambda u: owned[u])
        vidx = urls.index(victim)
        assert owned[victim] >= 3, owned
        svcs[vidx].fault_plan = FaultPlan.from_spec(
            "service.cell:kill,after=2")
        client = _fleet_client([victim] + [u for u in urls if u != victim])
        res = Session(backend=ServiceBackend(client=client)).run(study)
        assert res.records == reference.records
        assert svcs[vidx].dead, "injected kill never fired"
        assert client.client_stats()["failovers"] >= 1
        duplicates = _total_simulated(svcs) - cells
        assert 0 <= duplicates <= replication, \
            f"{duplicates} duplicate sims for replication={replication}"


def test_drain_during_forward_falls_back_locally(tmp_path):
    """A draining owner 503s the forwarded read-through; the requester
    counts a fallback and simulates locally — correct result, no error."""
    with mesh_trio(tmp_path) as (svcs, urls):
        bench, cfg, seed, key = _pick_cell(svcs[0].mesh, urls[0])
        out = SweepClient(urls[0], timeout=30.0).drain(wait_seconds=0.1)
        assert out["draining"]
        requester = next(s for s, u in zip(svcs, urls) if u != urls[0])
        res, src = requester.cell_with_source(bench, cfg, 128, seed)
        assert src == "simulated"
        assert requester.counters["peer_fallbacks"] == 1
        assert requester.counters["peer_hits"] == 0
        assert svcs[0].counters["simulated"] == 0
        ref = api.Session().run(
            Study(machines={"ws8": cfg}, benches=(bench,), n_threads=128,
                  seeds=(seed,)))
        assert res == ref.records[0].result


def test_full_partition_degrades_to_local_simulation(tmp_path):
    """Every peer unreachable: the daemon simulates everything itself,
    records bit-identical, peer_hits zero — the mesh is an optimization,
    never a correctness dependency."""
    study = _study(seeds=(0, 1))
    reference = api.Session().run(study)
    svc = SweepService(str(tmp_path / "lone"), persist_traces=False,
                       mesh=False)
    with _daemon(svc) as url:
        svc.configure_mesh(MeshConfig.build(
            url, [url, _dead_url(), _dead_url()], replication=2))
        res = SweepClient(url, timeout=120.0).study(study)
    assert res.records == reference.records
    assert svc.counters["simulated"] == len(study.cells())
    assert svc.counters["peer_hits"] == 0
    assert svc.counters["peer_fallbacks"] >= 1
    assert res.stats["peer_hits"] == 0


def test_peer_cell_key_mismatch_rejected(tmp_path):
    """Version/model skew guard: a forwarded request whose claimed key
    doesn't match the peer's own computation is a 400, not a silent
    wrong-key cache poisoning."""
    with mesh_trio(tmp_path) as (svcs, urls):
        with pytest.raises(ServiceError) as ei:
            SweepClient(urls[0], timeout=30.0)._get(
                "/peer/cell?bench=BFS&machine=ws8&n_threads=128"
                "&key=deadbeef")
        assert ei.value.code == 400
        assert not ei.value.is_transient


# -------------------------------------------------- queue-job federation

def test_queue_job_replicated_and_adopted_cross_daemon(tmp_path):
    """A job enqueued on daemon A is leaseable from a sibling even after
    A dies: the snapshot was replicated on enqueue and the sibling
    adopts it on first touch."""
    spec = _study(benches=("BFS",)).to_spec()
    cells = len(spec.cells())
    with mesh_trio(tmp_path) as (svcs, urls):
        job = svcs[0].enqueue(spec, chunk_size=2, lease_seconds=60.0)
        assert svcs[0].counters["jobs_replicated"] >= 1
        assert sum(s.counters["job_replicas_received"]
                   for s in svcs[1:]) >= 1
        svcs[0].kill()      # enqueuing daemon plays dead
        n = run_worker(urls, job["job"], worker_id="mesh-w1",
                       poll_seconds=0.01, sleep=_noop_sleep)
        assert n == cells
        adopters = [s for s in svcs[1:]
                    if s.counters["jobs_adopted_from_peers"]]
        assert len(adopters) == 1
        status = adopters[0].queue_status(job["job"])
        assert status["completed"] == status["chunks"] > 0


def test_queue_backend_survives_enqueuing_daemon_death(tmp_path):
    """The QueueBackend un-pinning satellite, end-to-end: the daemon that
    took the enqueue is killed on the first lease; the worker rotates to
    a sibling, which adopts the job from its replica; the study result
    is bit-identical to in-process."""
    study = _study(seeds=(0, 1))
    reference = api.Session().run(study)
    plans = (FaultPlan.from_spec("server/queue/lease:kill,times=1"),
             None, None)
    with mesh_trio(tmp_path, fault_plans=plans) as (svcs, urls):
        client = _fleet_client(urls)
        res = Session(backend=QueueBackend(
            client=client, chunk_size=2, poll_seconds=0.01)).run(study)
        assert res.records == reference.records
        assert svcs[0].dead, "injected kill never fired"
        assert res.stats["queue_cells_computed"] == len(study.cells())
        assert sum(s.counters["jobs_adopted_from_peers"]
                   for s in svcs[1:]) == 1


def test_job_replica_survives_daemon_restart(tmp_path):
    """replica.<job>.json round-trips a restart: a fresh daemon over the
    replica holder's root still adopts the job with no peers alive."""
    spec = _study(benches=("BFS",), seeds=(0,)).to_spec()
    with mesh_trio(tmp_path) as (svcs, urls):
        job = svcs[0].enqueue(spec, chunk_size=4, lease_seconds=60.0)
        holder_idx = next(i for i in (1, 2)
                          if svcs[i].counters["job_replicas_received"])
    heir = SweepService(str(tmp_path / f"root{holder_idx}"),
                        persist_traces=False, mesh=False)
    status = heir.queue_status(job["job"])      # adopts from replica file
    assert status["chunks"] == job["chunks"]
    assert heir.counters["jobs_adopted_from_peers"] == 1


# ----------------------------------------------------- worker fleet arg

def test_worker_urls_accepts_all_fleet_shapes():
    assert _worker_urls("http://a:1") == ["http://a:1"]
    assert _worker_urls(" http://a:1/ , http://b:2,http://a:1") \
        == ["http://a:1", "http://b:2"]
    assert _worker_urls(["http://a:1/", "http://b:2"]) \
        == ["http://a:1", "http://b:2"]
    rc = ResilientClient(["http://a:1", "http://b:2"])
    assert _worker_urls(rc) == ["http://a:1", "http://b:2"]
    sc = SweepClient("http://a:1/")
    assert _worker_urls(sc) == ["http://a:1"]
    with pytest.raises(ValueError):
        _worker_urls("  ,  ")


def test_worker_rotates_on_unknown_job_and_raises_when_all_refuse(
        tmp_path):
    """Two NON-mesh daemons over disjoint roots: the job lives only on
    B. A worker given [A, B] gets A's definite 400, rotates to B, and
    drains — but a job nobody knows still dies loudly fleet-wide."""
    svc_a = SweepService(str(tmp_path / "a"), persist_traces=False,
                         mesh=False)
    svc_b = SweepService(str(tmp_path / "b"), persist_traces=False,
                         mesh=False)
    spec = _study(benches=("BFS",), seeds=(0,)).to_spec()
    with _daemon(svc_a) as url_a, _daemon(svc_b) as url_b:
        job = svc_b.enqueue(spec, chunk_size=4, lease_seconds=60.0)
        n = run_worker([url_a, url_b], job["job"], worker_id="rot-w1",
                       poll_seconds=0.01, sleep=_noop_sleep)
        assert n == len(spec.cells())
        assert svc_b.queue_status(job["job"])["completed"] > 0
        with pytest.raises(ServiceError) as ei:
            run_worker([url_a, url_b], "job-nobody-1", sleep=_noop_sleep)
        assert ei.value.code == 400


def test_worker_survives_enqueuing_daemon_death_via_fleet(tmp_path):
    """The satellite headline: run_worker given the whole fleet keeps
    draining when the enqueuing daemon dies mid-job (transient failures
    rotate; the mesh sibling adopts)."""
    spec = _study(seeds=(0,)).to_spec()
    cells = len(spec.cells())
    plans = (FaultPlan.from_spec("server/queue/renew:kill,times=1"),
             None, None)
    with mesh_trio(tmp_path, fault_plans=plans) as (svcs, urls):
        job = svcs[0].enqueue(spec, chunk_size=2, lease_seconds=60.0)
        n = run_worker(urls, job["job"], worker_id="die-w1",
                       poll_seconds=0.01, sleep=_noop_sleep)
        assert svcs[0].dead
        assert n >= cells   # >= — the killed daemon's chunk may recompute
        survivor = next(s for s in svcs[1:]
                        if s.counters["jobs_adopted_from_peers"])
        assert survivor.queue_status(job["job"])["completed"] > 0


# ------------------------------------------------------- observability

def test_stats_and_healthz_surface_mesh_state(tmp_path):
    with mesh_trio(tmp_path) as (svcs, urls):
        svcs[1].cell("BFS", machines.baseline(8), 128, 0)
        client = SweepClient(urls[1], timeout=30.0)
        stats = client.stats()["mesh"]
        assert stats["enabled"] is True
        assert stats["self"] == urls[1]
        assert sorted(stats["peers"]) == sorted(
            [urls[0], urls[2]])
        assert stats["replication"] == 2
        for k in ("peer_forwards", "peer_hits", "peer_fallbacks",
                  "peer_serves", "replicas_sent", "replicas_adopted",
                  "replica_send_failures", "jobs_replicated",
                  "jobs_adopted_from_peers", "job_replicas_held"):
            assert k in stats
        health = client.healthz()["mesh"]
        assert health["enabled"] is True and health["self"] == urls[1]
    lone = SweepService(str(tmp_path / "nomesh"), persist_traces=False,
                        mesh=False)
    with _daemon(lone) as url:
        c = SweepClient(url, timeout=30.0)
        assert c.stats()["mesh"] == {"enabled": False}
        assert c.healthz()["mesh"] == {"enabled": False}
