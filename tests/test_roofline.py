"""HLO analyzer: exact FLOPs on known programs, while-trip correction,
collective accounting; roofline report math."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.config import get_shape
from repro.roofline import hlo_analysis as H
from repro.roofline.report import (
    Roofline, count_params, model_flops, structural_memory_bytes,
)


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops_exact():
    a = jnp.zeros((64, 32))
    b = jnp.zeros((32, 48))
    txt = _compile_text(lambda a, b: a @ b, a, b)
    stats = H.analyze(txt)
    assert stats.flops == 2 * 64 * 32 * 48
    assert stats.dot_count == 1


def test_scan_matmul_while_corrected():
    L, M, K, N = 5, 16, 32, 16

    def f(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    ws = jnp.zeros((L, K, K))
    x = jnp.zeros((M, K))
    stats = H.analyze(_compile_text(f, ws, x))
    assert stats.flops == L * 2 * M * K * K    # x L, not x 1


def test_nested_scan_flops():
    L1, L2, M, K = 3, 4, 8, 16

    def f(ws, x):
        def outer(x, w):
            def inner(x, _):
                return jnp.tanh(x @ w), None
            x, _ = jax.lax.scan(inner, x, jnp.arange(L2))
            return x, None
        out, _ = jax.lax.scan(outer, x, ws)
        return out

    stats = H.analyze(_compile_text(f, jnp.zeros((L1, K, K)),
                                    jnp.zeros((M, K))))
    assert stats.flops == L1 * L2 * 2 * M * K * K


def test_type_bytes_parse():
    assert H._type_bytes("bf16[8,4]") == 64
    assert H._type_bytes("f32[2,2]{1,0}") == 16
    assert H._type_bytes("(f32[2], s32[3])") == 8 + 12
    assert H._type_bytes("pred[]") == 1


def test_collective_bytes_on_sharded_program():
    n = len(jax.devices())
    if n < 2:
        pytest.skip("needs >1 device")
    mesh = jax.sharding.Mesh(np.array(jax.devices()), ("d",))
    x = jnp.zeros((n * 4, 8))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("d"))
    with mesh:
        txt = (jax.jit(lambda x: x.sum(), in_shardings=sh)
               .lower(x).compile().as_text())
    stats = H.analyze(txt)
    assert stats.total_collective_bytes > 0


# --------------------------------------------------------------- report

def test_count_params_tinyllama_close_to_published():
    cfg = get_config("tinyllama-1.1b")
    n = count_params(cfg)
    assert 1.0e9 < n < 1.25e9         # 1.1B + TP padding overhead


def test_count_params_mistral_large():
    cfg = get_config("mistral-large-123b")
    n = count_params(cfg)
    assert 1.15e11 < n < 1.35e11


def test_moe_active_params_much_smaller():
    cfg = get_config("deepseek-moe-16b")
    assert count_params(cfg, active_only=True) < 0.35 * count_params(cfg)


def test_roofline_terms_and_dominant():
    r = Roofline(arch="a", shape="s", mesh="m", chips=2,
                 flops_per_device=197e12,          # exactly 1s compute
                 bytes_per_device=819e9 / 2,       # 0.5s memory (hlo)
                 collective_bytes_per_device=50e9 / 4,
                 collective_breakdown={}, model_flops_total=2 * 197e12,
                 memory_model_bytes=819e9 / 2)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(0.5)
    assert r.collective_s == pytest.approx(0.25)
    assert r.dominant == "compute"
    assert r.useful_flops_ratio == pytest.approx(1.0)
    assert r.roofline_fraction == pytest.approx(1.0)


def test_structural_memory_decode_dominated_by_cache_and_params():
    cfg = get_config("mistral-large-123b")
    shape = get_shape("decode_32k")
    b = structural_memory_bytes(cfg, shape, "decode",
                                {"data": 16, "model": 16})
    p_loc = count_params(cfg) / 256 * 2
    assert b > p_loc                  # params + cache
    assert b < 100e9                  # sane bound per device


def test_model_flops_kinds():
    cfg = get_config("tinyllama-1.1b")
    tr = model_flops(cfg, get_shape("train_4k"), "train")
    pf = model_flops(cfg, get_shape("prefill_32k"), "prefill")
    dc = model_flops(cfg, get_shape("decode_32k"), "decode")
    assert tr == pytest.approx(3 * model_flops(cfg, get_shape("train_4k"),
                                               "prefill"))
    assert dc < pf < tr
