"""warpsim.obs tests: metric registry semantics + Prometheus exposition,
the X-Warpsim-Op header codec, span ring bounds, ambient-context
propagation, deterministic sampling, the counter-drift guard between the
legacy ``stats()`` views and the registry, and the chaos property that a
retried request stays ONE logical trace (attempt spans chain, traces
never fork)."""

import math
import threading

import pytest

from repro.core.warpsim import machines
from repro.core.warpsim import obs as obs_mod
from repro.core.warpsim import service as service_mod
from repro.core.warpsim.api import Study
from repro.core.warpsim.faults import FaultPlan
from repro.core.warpsim.obs import (
    DEFAULT_RING, OP_HEADER, CounterView, MetricsRegistry, Observability,
    TraceBuffer, format_op_header, parse_exposition, parse_op_header,
)
from repro.core.warpsim.service import ResilientClient, SweepService, serve


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _noop_sleep(_seconds):
    pass


class _daemon:
    """Context manager: serve `svc` on an ephemeral port, yield its URL."""

    def __init__(self, svc):
        self.svc = svc

    def __enter__(self):
        self.httpd = serve(self.svc)
        self.thread = threading.Thread(
            target=self.httpd.serve_forever, daemon=True)
        self.thread.start()
        return "http://%s:%d" % self.httpd.server_address[:2]

    def __exit__(self, *exc):
        self.httpd.shutdown()
        self.httpd.server_close()
        return False


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


def test_counter_semantics():
    reg = MetricsRegistry(clock=FakeClock())
    c = reg.counter("warpsim_test_total", "doc")
    c.inc()
    c.inc(3)
    assert c.value == 4
    with pytest.raises(ValueError, match="cannot decrease"):
        c.inc(-1)


def test_gauge_semantics():
    reg = MetricsRegistry(clock=FakeClock())
    g = reg.gauge("warpsim_test_gauge", "doc")
    g.set(5)
    g.inc()
    g.dec(2)
    assert g.value == 4


def test_histogram_buckets_and_timer():
    clock = FakeClock()
    reg = MetricsRegistry(clock=clock)
    h = reg.histogram("warpsim_test_seconds", "doc",
                      buckets=(0.1, 1.0, 10.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(100.0)     # lands in +Inf
    with h.time():
        clock.t += 2.0   # lands in the 10.0 bucket
    child = h._default()
    assert child.count == 4
    assert child.sum == pytest.approx(102.55)
    # Rendered buckets are cumulative and end at +Inf == count.
    samples = parse_exposition(reg.render())
    assert samples['warpsim_test_seconds_bucket{le="0.1"}'] == 1
    assert samples['warpsim_test_seconds_bucket{le="1"}'] == 2
    assert samples['warpsim_test_seconds_bucket{le="10"}'] == 3
    assert samples['warpsim_test_seconds_bucket{le="+Inf"}'] == 4
    assert samples["warpsim_test_seconds_count"] == 4


def test_labels_create_distinct_series():
    reg = MetricsRegistry(clock=FakeClock())
    c = reg.counter("warpsim_cells_total", "doc", labelnames=("engine",))
    c.labels(engine="fast").inc(2)
    c.labels(engine="native").inc()
    samples = parse_exposition(reg.render())
    assert samples['warpsim_cells_total{engine="fast"}'] == 2
    assert samples['warpsim_cells_total{engine="native"}'] == 1
    with pytest.raises(ValueError, match="takes labels"):
        c.labels(bench="BFS")
    with pytest.raises(ValueError, match="has labels"):
        c.inc()


def test_registration_is_idempotent_but_shape_strict():
    reg = MetricsRegistry(clock=FakeClock())
    a = reg.counter("warpsim_x_total", "doc")
    assert reg.counter("warpsim_x_total", "other doc") is a
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("warpsim_x_total")
    with pytest.raises(ValueError, match="already registered"):
        reg.counter("warpsim_x_total", labelnames=("k",))
    with pytest.raises(ValueError, match="bad metric name"):
        reg.counter("warpsim bad name")


def test_exposition_has_help_and_type_and_parses():
    reg = MetricsRegistry(clock=FakeClock())
    reg.counter("warpsim_a_total", "things counted").inc()
    text = reg.render()
    assert "# HELP warpsim_a_total things counted" in text
    assert "# TYPE warpsim_a_total counter" in text
    assert parse_exposition(text) == {"warpsim_a_total": 1.0}
    with pytest.raises(ValueError, match="malformed"):
        parse_exposition("no_value_here\n")


def test_snapshot_flattens_histograms():
    reg = MetricsRegistry(clock=FakeClock())
    reg.counter("warpsim_a_total").inc(2)
    reg.histogram("warpsim_b_seconds", buckets=(1.0,)).observe(0.5)
    snap = reg.snapshot()
    assert snap["warpsim_a_total"] == {"": 2.0}
    assert snap["warpsim_b_seconds"] == {".sum": 0.5, ".count": 1}


# ---------------------------------------------------------------------------
# CounterView: the legacy dict shape over registry counters
# ---------------------------------------------------------------------------


def test_counter_view_is_mapping_and_strict():
    reg = MetricsRegistry(clock=FakeClock())
    view = CounterView(reg, {"simulated": ("warpsim_sim_total", "doc"),
                             "hits": ("warpsim_hits_total", "doc")})
    view.inc("simulated")
    view.inc("hits", 3)
    assert view["simulated"] == 1
    assert dict(view) == {"simulated": 1, "hits": 3}
    assert len(view) == 2
    with pytest.raises(KeyError, match="not in this view"):
        view.inc("typo")
    assert view.metric_names() == {"simulated": "warpsim_sim_total",
                                   "hits": "warpsim_hits_total"}
    # The value genuinely lives in the registry, not a shadow dict.
    assert reg.get("warpsim_hits_total").value == 3


# ---------------------------------------------------------------------------
# Counter drift: legacy stats() views <-> registry, both directions
# ---------------------------------------------------------------------------


def _registry_counter_names(registry):
    return {n for n in registry.names()
            if isinstance(registry.get(n), obs_mod.Counter)}


def test_service_counters_match_registry_both_ways(tmp_path):
    svc = SweepService(str(tmp_path), persist_traces=False)
    view_names = set(svc.counters.metric_names().values())
    # ->: every legacy counter is a registered registry counter.
    assert view_names <= _registry_counter_names(svc.obs.registry)
    # <-: every registry counter is reachable through the legacy view —
    # nothing counts into /metrics that /stats can't see.
    assert _registry_counter_names(svc.obs.registry) <= view_names
    # The legacy dict shape is exactly the view's keys.
    assert set(svc.stats()["counters"]) == set(svc.counters)
    assert set(svc.counters) == set(service_mod._COUNTER_METRICS)


def test_client_counters_match_registry_both_ways():
    client = ResilientClient(["http://127.0.0.1:1"], sleep=_noop_sleep)
    view_names = set(client.counters.metric_names().values())
    counter_names = _registry_counter_names(client.obs.registry)
    assert view_names == counter_names
    legacy = client.client_stats()
    assert set(legacy) - {"endpoints"} == set(client.counters)
    assert set(client.counters) == set(service_mod._CLIENT_COUNTER_METRICS)


def test_bump_of_undeclared_counter_raises(tmp_path):
    # The drift guard at runtime: a typo'd bump can't mint a counter.
    svc = SweepService(str(tmp_path), persist_traces=False)
    with pytest.raises(KeyError, match="not in this view"):
        svc.bump("simualted")


# ---------------------------------------------------------------------------
# Header codec
# ---------------------------------------------------------------------------


def test_header_round_trip():
    ob = Observability(clock=FakeClock())
    with obs_mod.start_trace("study", obs=ob) as ctx:
        value = format_op_header("op-7", ctx)
        op, tid, sid = parse_op_header(value)
        assert op == "op-7"
        assert tid == ctx.trace_id
        assert sid == ctx.span_id


def test_header_bare_legacy_value_parses_as_pure_op():
    assert parse_op_header("cell-abc123") == ("cell-abc123", None, None)
    assert parse_op_header(None) == ("", None, None)
    assert parse_op_header("") == ("", None, None)


def test_header_without_context_is_just_the_op():
    assert format_op_header("op-1", None) == "op-1"
    assert obs_mod.trace_headers(None) == {}


def test_trace_headers_carry_ambient_context():
    ob = Observability(clock=FakeClock())
    with obs_mod.start_trace("study", obs=ob) as ctx:
        headers = obs_mod.trace_headers()
        op, tid, sid = parse_op_header(headers[OP_HEADER])
        assert (op, tid, sid) == ("", ctx.trace_id, ctx.span_id)


def test_non_recording_context_propagates_nothing(monkeypatch):
    monkeypatch.setenv("WARPSIM_OBS_SAMPLE", "0")
    ob = Observability(clock=FakeClock())
    with obs_mod.start_trace("study", obs=ob) as ctx:
        assert ctx.recording is False
        assert obs_mod.trace_headers() == {}
    assert ob.spans.dump() == []


# ---------------------------------------------------------------------------
# Span ring + context propagation
# ---------------------------------------------------------------------------


def test_ring_is_bounded_and_counts_lifetime():
    buf = TraceBuffer(maxlen=4)
    for i in range(10):
        buf.record({"trace": "t", "span": str(i)})
    assert len(buf) == 4
    assert buf.recorded == 10
    assert [s["span"] for s in buf.dump()] == ["6", "7", "8", "9"]


def test_ring_default_capacity_from_env(monkeypatch):
    monkeypatch.delenv("WARPSIM_OBS_RING", raising=False)
    assert TraceBuffer().maxlen == DEFAULT_RING
    monkeypatch.setenv("WARPSIM_OBS_RING", "16")
    assert TraceBuffer().maxlen == 16


def test_spans_nest_and_parent_correctly():
    ob = Observability(clock=FakeClock())
    with obs_mod.start_trace("study", obs=ob, backend="inprocess") as root:
        with obs_mod.span("inner") as inner:
            obs_mod.event("fault", point="p")
            assert inner.trace_id == root.trace_id
    spans = {s["name"]: s for s in ob.spans.dump(root.trace_id)}
    assert set(spans) == {"study", "inner", "fault"}
    assert spans["study"]["parent"] is None
    assert spans["inner"]["parent"] == root.span_id
    assert spans["fault"]["parent"] == spans["inner"]["span"]
    assert spans["study"]["attrs"] == {"backend": "inprocess"}
    assert spans["fault"]["dur_s"] == 0.0


def test_nested_start_trace_extends_instead_of_forking():
    ob = Observability(clock=FakeClock())
    with obs_mod.start_trace("outer", obs=ob) as outer:
        with obs_mod.start_trace("inner", obs=ob) as inner:
            assert inner.trace_id == outer.trace_id
    assert ob.spans.traces() == [
        {"trace": outer.trace_id, "spans": 2, "root": "outer"}]


def test_join_trace_parents_to_remote_span():
    ob = Observability(clock=FakeClock())
    with obs_mod.join_trace("abcd1234", "server/study", obs=ob,
                            parent="ffff00001111"):
        pass
    (s,) = ob.spans.dump("abcd1234")
    assert s["parent"] == "ffff00001111"
    assert s["name"] == "server/study"


def test_join_trace_without_id_is_passthrough():
    ob = Observability(clock=FakeClock())
    with obs_mod.join_trace(None, "server/study", obs=ob) as ctx:
        assert ctx is None
    assert ob.spans.dump() == []


def test_activate_reenters_context_in_another_thread():
    ob = Observability(clock=FakeClock())
    got = {}
    with obs_mod.start_trace("study", obs=ob) as ctx:
        def task():
            # A bare pool thread has no ambient context...
            got["before"] = obs_mod.current()
            with obs_mod.activate(ctx):
                got["during"] = obs_mod.current()
                with obs_mod.span("pool-task"):
                    pass
        t = threading.Thread(target=task)
        t.start()
        t.join()
    assert got["before"] is None
    assert got["during"] is ctx
    names = [s["name"] for s in ob.spans.dump(ctx.trace_id)]
    assert "pool-task" in names


def test_activate_none_is_passthrough():
    with obs_mod.activate(None) as ctx:
        assert ctx is None


# ---------------------------------------------------------------------------
# Stage profiling + the WARPSIM_OBS kill switch
# ---------------------------------------------------------------------------


def test_stage_observes_histogram_and_records_span():
    clock = FakeClock()
    ob = Observability(clock=clock)
    with obs_mod.start_trace("study", obs=ob) as ctx:
        with obs_mod.stage("engine", engine="fast"):
            clock.t += 0.25
    child = ob.stage_seconds.labels(stage="engine")
    assert child.count == 1
    assert child.sum == pytest.approx(0.25)
    names = [s["name"] for s in ob.spans.dump(ctx.trace_id)]
    assert "engine" in names


def test_stage_without_trace_still_observes_histogram():
    # Library code calls stage() unconditionally; with no active trace
    # the duration still lands in the ambient (default) histogram.
    before = obs_mod.default().stage_seconds.labels(stage="t_obs_x").count
    with obs_mod.stage("t_obs_x"):
        pass
    after = obs_mod.default().stage_seconds.labels(stage="t_obs_x").count
    assert after == before + 1


def test_kill_switch_makes_hooks_no_ops(monkeypatch):
    monkeypatch.setenv("WARPSIM_OBS", "0")
    ob = Observability(clock=FakeClock())
    with obs_mod.start_trace("study", obs=ob) as ctx:
        assert ctx is None
        with obs_mod.span("inner") as inner:
            assert inner is None
        obs_mod.event("fault")
        with obs_mod.stage("engine"):
            pass
    assert ob.spans.dump() == []
    with obs_mod.join_trace("sometid", "server/x", obs=ob) as ctx:
        assert ctx is None
    assert ob.spans.dump() == []


def test_sampling_is_deterministic_per_trace_id():
    # The decision is a pure function of the trace id and the rate.
    assert obs_mod._sampled("deadbeef") is True          # default rate 1.0
    for tid in ("a1", "b2", "c3"):
        first = obs_mod._sampled(tid)
        assert all(obs_mod._sampled(tid) == first for _ in range(3))


def test_sampling_rate_extremes(monkeypatch):
    monkeypatch.setenv("WARPSIM_OBS_SAMPLE", "1.0")
    assert obs_mod._sampled("anything") is True
    monkeypatch.setenv("WARPSIM_OBS_SAMPLE", "0.0")
    assert obs_mod._sampled("anything") is False


# ---------------------------------------------------------------------------
# Chaos: a retried request stays ONE trace (attempt spans, no fork)
# ---------------------------------------------------------------------------


def test_retried_request_keeps_one_logical_span_chain(tmp_path):
    """An injected 503 on the first /study attempt: the retry re-sends
    the same op (marker-keyed plan passes it) and the SAME trace id —
    both server hops land in one trace, parented to their respective
    client attempt spans. Retries append attempts; they never fork."""
    plan = FaultPlan.from_spec("server/study:error=503,times=1")
    svc = SweepService(str(tmp_path), persist_traces=False, fault_plan=plan)
    ob = Observability()
    with _daemon(svc) as url:
        client = ResilientClient([url], sleep=_noop_sleep)
        with obs_mod.start_trace("study", obs=ob) as ctx:
            tid = ctx.trace_id
            result = client.study(Study(
                machines={"ws8": machines.baseline(8)},
                benches=("BFS",), n_threads=128))
        assert result.records
    local = ob.spans.dump(tid)
    attempts = [s for s in local if s["name"] == "client.attempt"]
    assert len(attempts) == 2                      # the 503 + the retry
    assert attempts[0]["attrs"]["op"] == attempts[1]["attrs"]["op"]
    # The daemon saw both hops on the SAME trace — nothing forked.
    server = svc.obs.spans.dump(tid)
    study_spans = [s for s in server if s["name"] == "server/study"]
    assert len(study_spans) == 2
    attempt_ids = {s["span"] for s in attempts}
    assert {s["parent"] for s in study_spans} <= attempt_ids
    # Every span the daemon recorded belongs to this one trace.
    assert {t["trace"] for t in svc.obs.spans.traces()} == {tid}
    assert svc.counters["faults_injected"] == 1
