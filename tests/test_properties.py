"""Hypothesis property tests on system invariants."""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dep: property tests need hypothesis")
import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.warpsim import machines
from repro.core.warpsim.coalesce import warp_transactions, warp_transactions_bytes
from repro.core.warpsim.divergence import expand_workload
from repro.core.warpsim.trace import Branch, Compute, Mem, Workload, correlated_outcomes
from repro.models import moe as moe_mod
from repro.optim import adamw, compression

settings = hypothesis.settings(max_examples=25, deadline=None)


# ------------------------------------------------------------- coalescing

@settings
@hypothesis.given(hnp.arrays(np.int64, st.integers(1, 64),
                             elements=st.integers(0, 1 << 20)))
def test_transactions_bounded(addrs):
    """1 <= #transactions <= #active threads; partial bytes <= 64."""
    t = warp_transactions(addrs)
    assert 1 <= len(t) <= len(addrs)
    blocks, nbytes = warp_transactions_bytes(addrs)
    assert (nbytes <= 64).all() and (nbytes > 0).all()
    assert len(blocks) == len(t)


@settings
@hypothesis.given(hnp.arrays(np.int64, st.integers(2, 64),
                             elements=st.integers(0, 1 << 16)))
def test_transactions_monotone_under_subset(addrs):
    """A subset of accesses can never need more transactions."""
    t_full = len(warp_transactions(addrs))
    t_half = len(warp_transactions(addrs[: len(addrs) // 2]))
    assert t_half <= t_full


@settings
@hypothesis.given(st.integers(0, 2 ** 31 - 1), st.floats(0.05, 0.95),
                  st.floats(0.0, 0.99))
def test_correlated_outcomes_marginal(seed, p, corr):
    rng = np.random.default_rng(seed)
    out = correlated_outcomes(rng, 4096, p, corr)
    assert out.dtype == bool and out.shape == (4096,)
    # marginal stays near p (runs widen the CI; generous band)
    assert abs(out.mean() - p) < 0.35 + 0.3 * corr


@settings
@hypothesis.given(st.integers(0, 1000), st.floats(0.1, 0.9))
def test_divergence_issue_bounds(seed, p):
    """SIMT issue slots are between the uniform case and full 2-side
    serialization."""
    wl = Workload("w", [Branch(p_taken=p, corr=0.5,
                               then=[Compute(3)], orelse=[Compute(3)])],
                  n_threads=128, seed=seed)
    cfg = machines.baseline(32)
    ops = expand_workload(wl, cfg)
    g = cfg.issue_cycles_per_group
    for w in ops:
        issue = sum(op.issue_cycles for op in w)
        assert g * (1 + 3) <= issue <= g * (1 + 3 + 3)


# ------------------------------------------------------------------- MoE

@settings
@hypothesis.given(st.integers(0, 10_000), st.integers(1, 4),
                  st.sampled_from([4, 8, 16]))
def test_sort_by_expert_is_injective_layout(seed, k, e):
    rng = np.random.default_rng(seed)
    t = 32
    idx = jnp.asarray(rng.integers(0, e, size=(t, k)))
    order, dest, block_expert, t_pad = moe_mod.sort_by_expert(idx, e, block=8)
    d = np.asarray(dest)
    assert len(np.unique(d)) == t * k          # injective placement
    assert d.max() < t_pad
    be = np.asarray(block_expert)
    flat = np.asarray(idx).reshape(-1)
    sorted_e = flat[np.asarray(order)]
    for j in range(t * k):                     # row lands in own expert block
        assert be[d[j] // 8] == sorted_e[j]


# ------------------------------------------------------- optim invariants

@settings
@hypothesis.given(hnp.arrays(np.float32, st.integers(1, 64),
                             elements=st.floats(-1e3, 1e3, width=32)))
def test_quantize_error_bound(g):
    q, s = compression.quantize(jnp.asarray(g))
    back = np.asarray(compression.dequantize(q, s, jnp.float32))
    assert np.all(np.abs(back - g) <= float(s) * 0.5 + 1e-6)


@settings
@hypothesis.given(st.integers(0, 1000))
def test_adamw_step_finite_and_bounded(seed):
    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.standard_normal(8), jnp.float32)}
    grads = {"w": jnp.asarray(rng.standard_normal(8) * 100, jnp.float32)}
    cfg = adamw.AdamWConfig(lr=0.01, clip_norm=1.0, warmup_steps=0,
                            total_steps=10, min_lr_ratio=1.0)
    opt = adamw.init(params)
    new_params, new_opt, info = adamw.apply(cfg, grads, opt, params)
    delta = np.abs(np.asarray(new_params["w"] - params["w"]))
    assert np.isfinite(delta).all()
    # per-coordinate step is bounded by ~lr * (1 + wd*|w|)
    bound = 0.01 * (1.0 + 0.1 * np.abs(np.asarray(params["w"]))) + 1e-5
    assert (delta <= bound * 1.5).all()


# ------------------------------------------------- model-level invariants

@settings
@hypothesis.given(st.integers(0, 100))
def test_flash_attention_rowsum_one(seed):
    """softmax rows integrate to 1: attention output of constant V is V."""
    from repro.models import attention
    b, s, h, hd = 1, 16, 2, 8
    q = jax.random.normal(jax.random.PRNGKey(seed), (b, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, s, h, hd))
    v = jnp.ones((b, s, h, hd)) * 3.0
    pos = jnp.arange(s)
    out = attention.flash_attention(q, k, v, pos, pos, None, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(out), 3.0, rtol=1e-4)
