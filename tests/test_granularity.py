"""The paper-technique engine: SW+ expert-parallel dispatch and the int8
KV cache (the §Perf hillclimb features), tested on a real 2x2 device mesh."""

import ast
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.core import granularity
from repro.models import model as M, moe as moe_mod
from repro.models.config import ModelConfig


def test_granularity_binds_jax_through_compat():
    """jax-containment regression: granularity.py must not import jax
    directly — it binds the modules via ``compat.jax_modules()`` so
    version-drift shims stay in one reviewed place."""
    with open(granularity.__file__, encoding="utf-8") as fh:
        tree = ast.parse(fh.read())
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            assert not any(a.name.split(".")[0] == "jax" for a in node.names), (
                f"direct `import jax` at line {node.lineno}")
        elif isinstance(node, ast.ImportFrom):
            assert (node.module or "").split(".")[0] != "jax", (
                f"direct `from jax ...` import at line {node.lineno}")
    # The bound names are still the real modules, so behavior is intact.
    assert granularity.jax is compat.jax
    assert granularity.jnp is jnp
    assert granularity.Mesh is jax.sharding.Mesh
    assert granularity.P is jax.sharding.PartitionSpec


def _mesh():
    if len(jax.devices()) < 4:
        pytest.skip("needs 4 devices")
    return jax.sharding.Mesh(
        np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))


def _moe_cfg(**kw):
    base = dict(name="g-moe", family="moe", d_model=64, n_heads=4,
                n_kv_heads=4, head_dim=16, d_ff=0, vocab_size=128,
                moe_experts=8, moe_shared=0, moe_top_k=2, moe_d_ff=32,
                moe_capacity_factor=8.0, dtype="float32", tp_divisor=2)
    base.update(kw)
    return ModelConfig(**base).validate()


def test_sw_plus_ep_matches_oracle():
    mesh = _mesh()
    cfg = _moe_cfg()
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64))
    y_or, _ = moe_mod.dispatch_dense_oracle(params, x.reshape(-1, 64), cfg)
    granularity.set_mesh(mesh, ("data",))
    try:
        with mesh:
            y_ep, _ = jax.jit(lambda p, x: granularity.sw_plus_ep_layer(
                p, x, cfg, ("data",), block=8))(params, x)
    finally:
        granularity.set_mesh(None)
    np.testing.assert_allclose(np.asarray(y_ep.reshape(-1, 64)),
                               np.asarray(y_or), rtol=1e-4, atol=1e-5)


def test_sw_plus_ep_respects_budget_drops():
    """With a tight per-shard budget, overflow assignments drop (the SW+
    equivalent of capacity drops) without corrupting other tokens."""
    mesh = _mesh()
    cfg = _moe_cfg(moe_capacity_factor=0.1)
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64))
    granularity.set_mesh(mesh, ("data",))
    try:
        with mesh:
            y_ep, _ = jax.jit(lambda p, x: granularity.sw_plus_ep_layer(
                p, x, cfg, ("data",), block=8))(params, x)
    finally:
        granularity.set_mesh(None)
    assert bool(jnp.isfinite(y_ep).all())


def test_int8_kv_decode_accuracy():
    cfg = ModelConfig(name="kv8", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=128, dtype="float32").validate()
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    p = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 20), 0, 128)
    lp, c1 = M.prefill(p, cfg, {"tokens": toks[:, :8]}, max_len=24)
    lp8, c8 = M.prefill(p, cfg8, {"tokens": toks[:, :8]}, max_len=24)
    errs = [float(jnp.abs(lp - lp8).max())]
    for t in range(8, 16):
        l1, c1 = M.decode_step(p, cfg, toks[:, t:t + 1], c1)
        l8, c8 = M.decode_step(p, cfg8, toks[:, t:t + 1], c8)
        errs.append(float(jnp.abs(l1 - l8).max()))
    assert max(errs) < 0.02, errs


def test_int8_kv_cache_dtype_and_size():
    cfg = ModelConfig(name="kv8b", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=128, kv_cache_dtype="int8").validate()
    cache = M.init_decode_cache(cfg, batch=2, max_len=32)
    assert cache["kv"]["k"].dtype == jnp.int8
    assert cache["kv"]["k_scale"].dtype == jnp.bfloat16
    payload = cache["kv"]["k"].size
    scales = cache["kv"]["k_scale"].size * 2
    assert scales / payload < 0.2       # metadata overhead bounded


def test_seq_sharded_flash_decoding_matches_dense():
    """H-C2: sequence-sharded decode attention == dense softmax over the
    full cache, with no KV-head padding."""
    mesh = _mesh()
    B, Sc, H, hd = 2, 32, 3, 16      # 3 heads: NOT padded to TP degree
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Sc, H, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Sc, H, hd))
    positions = jnp.arange(Sc).at[20:].set(-1)    # only 20 filled
    pos = jnp.asarray(19)

    granularity.set_mesh(mesh, ("data",))
    try:
        with mesh:
            out = jax.jit(lambda q, k, v: granularity.
                          seq_sharded_decode_attention(
                              q, k, v, positions, pos, mesh=mesh))(q, k, v)
    finally:
        granularity.set_mesh(None)

    s = jnp.einsum("bhd,bkhd->bhk", q / (hd ** 0.5), k)
    valid = (positions >= 0) & (positions <= pos)
    s = jnp.where(valid[None, None, :], s, -2.0e38)
    p = jax.nn.softmax(s, -1)
    exp = jnp.einsum("bhk,bkhd->bhd", p, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)
