"""Data pipeline, optimizer, checkpoint, runtime substrates."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data import DataConfig, SyntheticCorpus
from repro.optim import adamw, compression
from repro.runtime import elastic, straggler
from repro.runtime.fault import FailureInjector, SimulatedFailure, resume_or_init


# -------------------------------------------------------------------- data

def test_data_deterministic():
    c = SyntheticCorpus(DataConfig(seed=7))
    b1, b2 = c.batch_at(3), c.batch_at(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_data_steps_differ():
    c = SyntheticCorpus(DataConfig(seed=7))
    assert not np.array_equal(c.batch_at(0)["tokens"],
                              c.batch_at(1)["tokens"])


def test_data_host_sharding_disjoint():
    a = SyntheticCorpus(DataConfig(n_hosts=2, host_index=0)).batch_at(0)
    b = SyntheticCorpus(DataConfig(n_hosts=2, host_index=1)).batch_at(0)
    assert a["tokens"].shape[0] == 4
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_data_labels_are_shifted_tokens():
    c = SyntheticCorpus(DataConfig())
    b = c.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ------------------------------------------------------------------- optim

def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                            total_steps=200, min_lr_ratio=1.0)
    opt = adamw.init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw.apply(cfg, grads, opt, params)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adamw_clips_gradients():
    params = {"w": jnp.ones(4)}
    cfg = adamw.AdamWConfig(clip_norm=1.0)
    opt = adamw.init(params)
    _, _, info = adamw.apply(cfg, {"w": jnp.full(4, 1e6)}, opt, params)
    assert float(info["grad_norm"]) > 1e5   # measured pre-clip


def test_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    assert float(adamw.schedule(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(adamw.schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1)


def test_quantize_roundtrip_error_bound():
    g = jax.random.normal(jax.random.PRNGKey(0), (256,))
    q, s = compression.quantize(g)
    back = compression.dequantize(q, s, jnp.float32)
    assert float(jnp.abs(back - g).max()) <= float(s) * 0.5 + 1e-6


def test_compressed_psum_matches_mean():
    if len(jax.devices()) < 2:
        pytest.skip("needs >=2 devices")
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:2]), ("data",))
    g = jax.random.normal(jax.random.PRNGKey(1), (2, 64))
    sharded = jax.device_put(g, jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data")))
    # compressed reduce must approximate the exact replica mean
    out = compression.compressed_psum_grads(
        {"g": sharded}, mesh, axis="data")["g"]
    expected = g.mean(0)
    assert out.shape == expected.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=0.05)


# -------------------------------------------------------------- checkpoint

def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16) * 1.5,
                       "step": jnp.asarray(7, jnp.int32)}}


def test_ckpt_roundtrip_bitwise():
    with tempfile.TemporaryDirectory() as d:
        tree = _tree()
        ckpt.save(d, 3, tree)
        out = ckpt.restore(d, jax.eval_shape(lambda: tree))
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            assert a.dtype == b.dtype


def test_ckpt_retention():
    with tempfile.TemporaryDirectory() as d:
        for s in range(6):
            ckpt.save(d, s, {"x": jnp.asarray(s)}, keep=2)
        assert ckpt.all_steps(d) == [4, 5]


def test_ckpt_restore_latest_and_specific():
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 5, 9):
            ckpt.save(d, s, {"x": jnp.asarray(float(s))}, keep=10)
        assert ckpt.latest_step(d) == 9
        out = ckpt.restore(d, {"x": jnp.asarray(0.0)}, step=5)
        assert float(out["x"]) == 5.0


def test_ckpt_atomicity_tmp_never_visible():
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, _tree())
        assert not any(n.endswith(".tmp") for n in os.listdir(d))


def test_async_checkpointer():
    with tempfile.TemporaryDirectory() as d:
        ac = ckpt.AsyncCheckpointer(d, keep=2)
        for s in range(3):
            ac.save(s, {"x": jnp.asarray(s)})
        ac.wait()
        assert ckpt.latest_step(d) == 2


# ----------------------------------------------------------------- runtime

def test_failure_injector_fires_once():
    with tempfile.TemporaryDirectory() as d:
        marker = os.path.join(d, "m")
        inj = FailureInjector(fail_at_step=3, marker_path=marker)
        inj.check(2)
        with pytest.raises(SimulatedFailure):
            inj.check(3)
        inj.check(3)    # second run: marker exists, no raise


def test_resume_or_init_fresh_and_restore():
    with tempfile.TemporaryDirectory() as d:
        init = lambda: {"w": jnp.zeros(3), "step": jnp.asarray(0)}
        state, start = resume_or_init(d, init)
        assert start == 0
        ckpt.save(d, 12, {"w": jnp.ones(3), "step": jnp.asarray(12)})
        state, start = resume_or_init(d, init)
        assert start == 12
        assert float(state["w"].sum()) == 3.0


def test_straggler_monitor_flags_outlier():
    mon = straggler.StragglerMonitor(window=20, k=4.0, min_samples=5)
    for i in range(10):
        mon.observe(i, 0.1 + 0.001 * (i % 3))
    ev = mon.observe(10, 1.0)
    assert ev is not None and ev.step == 10
    assert mon.observe(11, 0.1) is None


def test_elastic_rebuild_and_reshard():
    # lose half the devices (4 -> 2): rebuild the largest viable mesh
    n = len(jax.devices())
    keep = max(1, n // 2)
    mesh = elastic.rebuild_mesh(jax.devices()[:keep], model_parallel=1)
    assert mesh.devices.size == keep
    params = {"layers": {"w1": jnp.ones((2, 4, 8))}}   # (L, D, FF) stacked
    state = {"params": params,
             "opt": {"m": params, "v": params, "step": jnp.asarray(0)}}
    out = elastic.reshard_state(state, mesh)
    assert out["params"]["layers"]["w1"].shape == (2, 4, 8)


def test_elastic_viable_shapes():
    shapes = elastic.viable_mesh_shapes(7, model_parallel=2)
    assert shapes[0] == (3, 2)
