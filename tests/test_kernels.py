"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


_TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# -------------------------------------------------------------------- gmm

@pytest.mark.parametrize("m,k,n,e,bm", [
    (128, 64, 64, 2, 64),
    (256, 96, 80, 4, 64),       # non-multiple N/K -> internal padding
    (512, 128, 256, 8, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gmm_matches_ref(m, k, n, e, bm, dtype):
    x = _rand(0, (m, k), dtype)
    w = _rand(1, (e, k, n), dtype) * 0.1
    be = jax.random.randint(jax.random.PRNGKey(2), (m // bm,), 0, e)
    out = ops.moe_gmm(x, w, be, block=bm)
    exp = ref.gmm_ref(x, w, be, bm)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32),
        rtol=_TOL[dtype], atol=_TOL[dtype] * 8)


def test_gmm_block_expert_selects_weights():
    """Each row-block must use exactly its expert's weights."""
    m, k, n, e, bm = 128, 32, 32, 4, 64
    x = jnp.ones((m, k), jnp.float32)
    w = jnp.stack([jnp.full((k, n), i + 1.0) for i in range(e)])
    be = jnp.asarray([2, 0], jnp.int32)
    out = ops.moe_gmm(x, w, be, block=bm)
    assert float(out[0, 0]) == pytest.approx(3.0 * k)
    assert float(out[bm, 0]) == pytest.approx(1.0 * k)


# ----------------------------------------------------------------- gather

@pytest.mark.parametrize("t,d,tp", [(64, 32, 128), (200, 48, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_matches_ref(t, d, tp, dtype):
    x = _rand(3, (t, d), dtype)
    n = t // 2
    src = jax.random.randint(jax.random.PRNGKey(4), (n,), 0, t)
    dest = jax.random.permutation(jax.random.PRNGKey(5), tp)[:n]
    out = ops.coalesced_gather(x, src, dest, tp, block=64)
    row_src = jnp.zeros((tp,), jnp.int32).at[dest].set(src.astype(jnp.int32))
    row_valid = jnp.zeros((tp,), jnp.int32).at[dest].set(1)
    exp = ref.gather_rows_ref(x, row_src, row_valid, tp)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_gather_unfilled_rows_zero():
    x = jnp.ones((8, 16), jnp.float32)
    out = ops.coalesced_gather(x, jnp.asarray([0]), jnp.asarray([3]), 64,
                               block=64)
    assert float(out[3].sum()) == 16.0
    assert float(out.sum()) == 16.0


# ------------------------------------------------------------------ flash

@pytest.mark.parametrize("bh,s,hd,bq,bkv", [
    (2, 128, 64, 64, 64),
    (4, 256, 32, 128, 64),
    (1, 512, 128, 128, 128),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(bh, s, hd, bq, bkv, causal):
    q = _rand(6, (bh, s, hd), jnp.float32)
    k = _rand(7, (bh, s, hd), jnp.float32)
    v = _rand(8, (bh, s, hd), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, bq=bq, bkv=bkv)
    exp = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


def test_flash_tile_granularity_invariance():
    """The paper's warp-size knob: results must not depend on tile size."""
    q = _rand(9, (2, 256, 64), jnp.float32)
    k = _rand(10, (2, 256, 64), jnp.float32)
    v = _rand(11, (2, 256, 64), jnp.float32)
    outs = [ops.flash_attention(q, k, v, bq=bq, bkv=bkv)
            for bq, bkv in [(64, 64), (128, 64), (64, 128), (256, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    q = _rand(12, (2, 128, 64), jnp.bfloat16)
    k = _rand(13, (2, 128, 64), jnp.bfloat16)
    v = _rand(14, (2, 128, 64), jnp.bfloat16)
    out = ops.flash_attention(q, k, v)
    exp = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=3e-2, atol=3e-2)


# -------------------------------------------------------------------- ssd

def _ssd_seq_ref(x, da, b, c):
    bh, s, p = x.shape
    n = b.shape[-1]

    def step(h, t):
        xt, dat, bt, ct = t
        h = h * jnp.exp(dat)[:, None, None] + xt[:, :, None] * bt[:, None, :]
        return h, jnp.einsum("bn,bpn->bp", ct, h)

    h0 = jnp.zeros((bh, p, n))
    _, ys = jax.lax.scan(step, h0, (x.transpose(1, 0, 2), da.T,
                                    b.transpose(1, 0, 2),
                                    c.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2)


@pytest.mark.parametrize("bh,s,p,n,q", [
    (2, 64, 16, 8, 16),
    (3, 128, 32, 16, 32),
    (1, 256, 64, 32, 64),
])
def test_ssd_kernel_matches_sequential(bh, s, p, n, q):
    x = _rand(20, (bh, s, p), jnp.float32) * 0.5
    da = -jax.nn.softplus(_rand(21, (bh, s), jnp.float32))
    b = _rand(22, (bh, s, n), jnp.float32) * 0.3
    c = _rand(23, (bh, s, n), jnp.float32) * 0.3
    out = ops.ssd_scan(x, da, b, c, chunk=q)
    exp = _ssd_seq_ref(x, da, b, c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-4, atol=2e-5)


def test_ssd_kernel_chunk_invariance():
    """State carried in VMEM scratch must make chunking invisible."""
    x = _rand(24, (2, 128, 16), jnp.float32) * 0.5
    da = -jax.nn.softplus(_rand(25, (2, 128), jnp.float32))
    b = _rand(26, (2, 128, 8), jnp.float32) * 0.3
    c = _rand(27, (2, 128, 8), jnp.float32) * 0.3
    outs = [ops.ssd_scan(x, da, b, c, chunk=q) for q in (16, 32, 64, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=2e-4, atol=2e-5)
