"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + no NaNs (deliverable f)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs, runnable_shapes
from repro.models import model as M

ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    key = jax.random.PRNGKey(1)
    if cfg.frontend in ("audio", "vlm"):
        batch = {"input_embeds": jax.random.normal(
                     key, (B, S, cfg.d_model), jnp.float32),
                 "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}

    @jax.jit
    def loss_and_grad(p):
        return jax.value_and_grad(
            lambda q: M.train_loss(q, cfg, batch)[0])(p)

    loss, grads = loss_and_grad(params)
    assert jnp.isfinite(loss), (arch, loss)
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    if cfg.frontend in ("audio", "vlm"):
        batch = {"input_embeds": jnp.zeros((B, S, cfg.d_model))}
    else:
        batch = {"tokens": jnp.zeros((B, S), jnp.int32)}
    x = M.embed_inputs(params, cfg, batch)
    assert x.shape == (B, S, cfg.d_model)
    hid, aux = M.forward_hidden(params, cfg, x, jnp.arange(S))
    assert hid.shape == (B, S, cfg.d_model)
    logits = M.logits_fn(params, cfg, hid)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all()), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B = 2
    if cfg.frontend in ("audio", "vlm"):
        batch = {"input_embeds": jnp.zeros((B, 4, cfg.d_model))}
    else:
        batch = {"tokens": jnp.zeros((B, 4), jnp.int32)}
    logits, cache = M.prefill(params, cfg, batch, max_len=16)
    assert logits.shape == (B, cfg.vocab_padded)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits2, cache = M.decode_step(params, cfg, tok, cache)
    assert logits2.shape == (B, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits2).all()), arch


def test_all_archs_and_cells_accounted():
    """40 cells total: 10 archs x 4 shapes, with long_500k runnable only
    for the sub-quadratic archs (DESIGN.md §5)."""
    assert len(ARCHS) == 10
    cells = {(a, s) for a in ARCHS for s in runnable_shapes(a)}
    assert len(cells) == 10 * 3 + 2
    full = {(a, s) for a in ARCHS
            for s in ("train_4k", "prefill_32k", "decode_32k", "long_500k")}
    assert len(full) == 40


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_tp_divisibility(arch):
    """Every full config must shard on the 16-way model axis."""
    cfg = get_config(arch)
    tp = cfg.tp_divisor
    assert tp == 16
    assert cfg.d_model % tp == 0
    assert cfg.n_q_eff % tp == 0
    assert cfg.n_q_eff % cfg.n_kv_eff == 0
    assert cfg.vocab_padded % tp == 0
    if cfg.d_ff:
        assert cfg.d_ff % tp == 0
    if cfg.family == "moe":
        assert cfg.moe_experts_eff % tp == 0
    if cfg.family in ("ssm", "hybrid"):
        assert cfg.ssm_heads % tp == 0
        assert cfg.d_inner % tp == 0
