"""Sharding rules: spec validity on the production mesh shapes and
single-device vs sharded numerical equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import sharding
from repro.configs import get_config, list_archs
from repro.launch import steps as steps_lib
from repro.models import model as M
from repro.models.config import ModelConfig, get_shape


@pytest.mark.parametrize("arch", list_archs())
def test_param_specs_divisible_on_production_mesh(arch):
    """Every sharded dim of every full-config param must divide the
    production mesh axis (data=16, model=16)."""
    cfg = get_config(arch)
    params = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    specs = sharding.param_specs(params)
    axis_size = {"data": 16, "model": 16}

    def check(path, leaf, spec):
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            assert leaf.shape[dim] % axis_size[ax] == 0, (
                arch, [str(p) for p in path], leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        check, params, specs,
        is_leaf=lambda x: isinstance(x, P))


def test_data_axes_fallbacks():
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
    assert sharding.data_axes(mesh, 8) == ("data",)
    assert sharding.data_axes(mesh, 3) is None


def _tiny_cfg():
    return ModelConfig(name="shard-t", family="dense", n_layers=2,
                       d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                       d_ff=64, vocab_size=64, dtype="float32",
                       tp_divisor=2).validate()


def test_sharded_loss_matches_single_device():
    """The same train_loss on a 2x2 mesh must equal the unsharded value."""
    cfg = _tiny_cfg()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 64)
    batch = {"tokens": toks, "labels": toks}
    loss_ref, _ = M.train_loss(params, cfg, batch)

    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
    specs = sharding.param_specs(params)
    p_sh = jax.device_put(params, sharding.to_named(mesh, specs))
    dp = sharding.data_axes(mesh, 4)
    b_sh = jax.device_put(batch, sharding.to_named(
        mesh, sharding.batch_specs(batch, dp)))
    sharder = sharding.make_sharder(mesh, dp)
    with mesh:
        loss_sh, _ = jax.jit(
            lambda p, b: M.train_loss(p, cfg, b, sharder))(p_sh, b_sh)
    np.testing.assert_allclose(float(loss_ref), float(loss_sh),
                               rtol=2e-5)


def test_sharded_grads_match_single_device():
    cfg = _tiny_cfg()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 64)
    batch = {"tokens": toks, "labels": toks}

    grad_fn = jax.grad(lambda p, b: M.train_loss(p, cfg, b)[0])
    g_ref = grad_fn(params, batch)

    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
    specs = sharding.param_specs(params)
    p_sh = jax.device_put(params, sharding.to_named(mesh, specs))
    dp = sharding.data_axes(mesh, 4)
    b_sh = jax.device_put(batch, sharding.to_named(
        mesh, sharding.batch_specs(batch, dp)))
    with mesh:
        g_sh = jax.jit(grad_fn)(p_sh, b_sh)
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_sh)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


def test_build_step_structs_no_allocation():
    """build_step must work from ShapeDtypeStructs only (dry-run contract)."""
    cfg = get_config("tinyllama-1.1b", smoke=True)
    shape = get_shape("train_4k")
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
    fn, args, in_sh, out_sh = steps_lib.build_step(cfg, shape, mesh)
    flat = jax.tree.leaves(args)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in flat)


def test_cache_specs_keys():
    cfg = get_config("hymba-1.5b", smoke=True)
    cache = jax.eval_shape(lambda: M.init_decode_cache(cfg, 4, 64))
    specs = sharding.cache_specs(cache, "data")
    assert specs["kv"]["k"] == P(None, "data", None, "model", None)
    assert specs["ssm"]["h"] == P(None, "data", "model", None, None)
    assert specs["kv"]["positions"] == P(None)
