"""Model stack: layer correctness, decode==forward, MoE dispatch
equivalence, SSD oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as kref
from repro.models import attention, common, model as M, moe as moe_mod, ssm as S
from repro.models.config import ModelConfig


def _cfg(family="dense", **kw):
    base = dict(name=f"t-{family}", family=family, n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                vocab_size=128, dtype="float32")
    if family == "moe":
        base.update(d_ff=0, n_kv_heads=4,
                    moe_experts=8, moe_shared=1, moe_top_k=2, moe_d_ff=32)
    if family in ("ssm", "hybrid"):
        base.update(ssm_state=16, ssm_headdim=16, ssm_chunk=16)
    if family == "ssm":
        base.update(n_heads=1, n_kv_heads=1, pos_emb="none")
    base.update(kw)
    return ModelConfig(**base).validate()


# ----------------------------------------------------------------- layers

def test_rms_norm_unit_scale():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 32)) * 10
    y = common.rms_norm(x, jnp.ones(32))
    rms = jnp.sqrt(jnp.mean(y * y, -1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-3)


def test_rope_preserves_norm_and_relative_shift():
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 2, 16))
    pos = jnp.arange(8)
    y = common.apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)
    # dot(q_i, k_j) depends only on i-j
    q = jnp.ones((1, 8, 1, 16))
    k = jnp.ones((1, 8, 1, 16))
    qr = common.apply_rope(q, pos, 10000.0)[0, :, 0]
    kr = common.apply_rope(k, pos, 10000.0)[0, :, 0]
    d13 = float(qr[1] @ kr[3])
    d35 = float(qr[3] @ kr[5])
    assert d13 == pytest.approx(d35, rel=1e-5)


def test_flash_attention_vs_dense_reference():
    cfg = _cfg()
    b, s = 2, 64
    q = jax.random.normal(jax.random.PRNGKey(2), (b, s, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(3), (b, s, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(4), (b, s, 2, 16))
    pos = jnp.arange(s)
    out = attention.flash_attention(q, k, v, pos, pos, None, kv_chunk=16)
    # dense reference with GQA expansion
    k2 = jnp.repeat(k, 2, axis=2)
    v2 = jnp.repeat(v, 2, axis=2)
    exp = kref.flash_attention_ref(
        q.transpose(0, 2, 1, 3).reshape(-1, s, 16),
        k2.transpose(0, 2, 1, 3).reshape(-1, s, 16),
        v2.transpose(0, 2, 1, 3).reshape(-1, s, 16), causal=True)
    exp = exp.reshape(b, 4, s, 16).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_masks_old_tokens():
    b, s, w = 1, 32, 8
    q = jax.random.normal(jax.random.PRNGKey(5), (b, s, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(6), (b, s, 1, 16))
    v = jax.random.normal(jax.random.PRNGKey(7), (b, s, 1, 16))
    pos = jnp.arange(s)
    out_w = attention.flash_attention(q, k, v, pos, pos, w, kv_chunk=8)
    # manually windowed dense attention
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / 4.0
    mask = (pos[:, None] >= pos[None, :]) & (pos[:, None] - pos[None, :] < w)
    scores = jnp.where(mask[None, None], scores, -2e38)
    p = jax.nn.softmax(scores, -1)
    exp = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    np.testing.assert_allclose(np.asarray(out_w), np.asarray(exp),
                               rtol=2e-4, atol=2e-4)


def test_head_padding_zero_contribution():
    """TP pad heads must contribute nothing to the output."""
    cfg = _cfg(n_heads=3, n_kv_heads=3, tp_divisor=4)   # pads to 4
    assert cfg.n_q_eff == 4
    p = attention.attn_init(jax.random.PRNGKey(8), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(9), (1, 8, 64))
    out = attention.attention(p, x, jnp.arange(8), cfg)
    # zero the pad-head weights: output must be identical (masked anyway)
    hd = cfg.head_dim
    p2 = dict(p)
    p2["wq"] = p["wq"].at[:, 3 * hd:].set(0)
    out2 = attention.attention(p2, x, jnp.arange(8), cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               rtol=1e-5, atol=1e-6)


# -------------------------------------------------------------------- MoE

@pytest.fixture(scope="module")
def moe_setup():
    cfg = _cfg("moe", moe_shared=0, moe_capacity_factor=8.0)
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (96, 64))
    return cfg, params, x


def test_moe_lw_equals_oracle(moe_setup):
    cfg, params, x = moe_setup
    y_or, _ = moe_mod.dispatch_dense_oracle(params, x, cfg)
    y_lw, _ = moe_mod.dispatch_lw_plus(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y_lw), np.asarray(y_or),
                               rtol=1e-4, atol=1e-5)


def test_moe_sw_equals_oracle(moe_setup):
    cfg, params, x = moe_setup
    y_or, _ = moe_mod.dispatch_dense_oracle(params, x, cfg)
    y_sw, _ = moe_mod.dispatch_sw_plus(params, x, cfg, block=64)
    np.testing.assert_allclose(np.asarray(y_sw), np.asarray(y_or),
                               rtol=1e-4, atol=1e-5)


def test_moe_capacity_drops_tokens():
    """LW+ capacity sync: with tiny capacity, some tokens get zero output
    from the dropped assignment (paper: 'synchronizing through capacity')."""
    cfg = _cfg("moe", moe_shared=0, moe_capacity_factor=0.25)
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (128, 64))
    y_lw, _ = moe_mod.dispatch_lw_plus(params, x, cfg)
    y_or, _ = moe_mod.dispatch_dense_oracle(params, x, cfg)
    assert float(jnp.abs(y_lw - y_or).max()) > 1e-3


def test_moe_pad_experts_never_routed():
    cfg = _cfg("moe", moe_experts=6, tp_divisor=4)      # pads to 8
    assert cfg.moe_experts_eff == 8
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 64))
    _, idx, _ = moe_mod.router_probs(params, x, cfg)
    assert int(idx.max()) < 6


def test_sort_by_expert_layout():
    idx = jnp.asarray([[0, 2], [1, 2], [0, 1], [2, 0]])
    order, dest, block_expert, t_pad = moe_mod.sort_by_expert(idx, 4, block=4)
    flat = idx.reshape(-1)
    sorted_e = np.asarray(flat)[np.asarray(order)]
    assert (np.diff(sorted_e) >= 0).all()               # sorted by expert
    assert len(np.unique(np.asarray(dest))) == len(dest)  # injective
    be = np.asarray(block_expert)
    d = np.asarray(dest)
    for j, e in enumerate(sorted_e):                     # rows in own block
        assert be[d[j] // 4] == e


# -------------------------------------------------------------------- SSD

def test_ssd_chunked_vs_sequential():
    B, SQ, NH, P, N = 2, 48, 4, 8, 8
    x = jax.random.normal(jax.random.PRNGKey(0), (B, SQ, NH, P)) * 0.5
    dt = jax.random.normal(jax.random.PRNGKey(1), (B, SQ, NH))
    a_log = jnp.log(jnp.arange(1, NH + 1, dtype=jnp.float32))
    b = jax.random.normal(jax.random.PRNGKey(2), (B, SQ, 1, N)) * 0.3
    c = jax.random.normal(jax.random.PRNGKey(3), (B, SQ, 1, N)) * 0.3
    dsk = jnp.ones((NH,))
    y1, h1 = S.ssd_scan(x, dt, a_log, b, c, dsk, chunk=16)
    br = jnp.repeat(b, NH, 2)
    cr = jnp.repeat(c, NH, 2)
    y2, h2 = kref.ssd_chunk_ref(x, dt, a_log, br, cr, dsk, 16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-4, atol=1e-4)


def test_ssd_chunk_size_invariance():
    B, SQ, NH, P, N = 1, 64, 2, 8, 4
    x = jax.random.normal(jax.random.PRNGKey(4), (B, SQ, NH, P)) * 0.5
    dt = jnp.zeros((B, SQ, NH))
    a_log = jnp.zeros((NH,))
    b = jax.random.normal(jax.random.PRNGKey(5), (B, SQ, 1, N)) * 0.3
    c = jax.random.normal(jax.random.PRNGKey(6), (B, SQ, 1, N)) * 0.3
    outs = [S.ssd_scan(x, dt, a_log, b, c, jnp.ones(NH), chunk=q)[0]
            for q in (8, 16, 32, 64)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-4, atol=1e-4)


# -------------------------------------------- decode == full forward (all)

@pytest.mark.parametrize("family", ["dense", "moe", "ssm", "hybrid"])
def test_decode_matches_forward(family):
    kw = {}
    if family == "hybrid":
        kw["sliding_window"] = 12
    if family == "moe":
        kw["moe_capacity_factor"] = 8.0
    cfg = _cfg(family, **kw)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, SQ = 2, 20
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, SQ), 0,
                              cfg.vocab_size)
    x = M.embed_inputs(params, cfg, {"tokens": toks})
    hid, _ = M.forward_hidden(params, cfg, x, jnp.arange(SQ))
    full = M.logits_fn(params, cfg, hid)
    lp, cache = M.prefill(params, cfg, {"tokens": toks[:, :6]}, max_len=SQ)
    errs = [float(jnp.abs(lp - full[:, 5]).max())]
    for t in range(6, SQ):
        lg, cache = M.decode_step(params, cfg, toks[:, t:t + 1], cache)
        errs.append(float(jnp.abs(lg - full[:, t]).max()))
    assert max(errs) < 2e-3, errs


def test_train_loss_finite_and_masked():
    cfg = _cfg()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 128)
    labels = toks.at[:, :8].set(-1)       # mask half
    loss, metrics = M.train_loss(params, cfg, {"tokens": toks,
                                               "labels": labels})
    assert jnp.isfinite(loss)
    assert float(metrics["tokens"]) == 16.0
