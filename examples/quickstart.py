"""Quickstart: the paper's result in 60 seconds + a tiny LM training run.

1. Runs the warp-size study (SW+ vs LW+ vs fixed warp sizes) on two
   benchmarks and prints the headline comparison (paper Figs. 5-7).
2. Trains a tiny decoder LM for 20 steps on the synthetic corpus and
   shows the loss falling.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax

from repro.core.warpsim import machines, runner
from repro.configs import get_config
from repro.data import DataConfig, SyntheticCorpus
from repro.models import model as model_lib
from repro.optim import adamw


def warp_size_study():
    print("=== Warp-size study (paper reproduction, 2 benchmarks) ===")
    suite = machines.paper_suite()
    res = runner.run_suite(suite, benches=("BKP", "MU"))
    for m in ("ws8", "ws32", "ws64", "SW+", "LW+"):
        row = " ".join(f"{b}:{res[m][b].ipc:6.2f}" for b in res[m])
        print(f"  {m:4s} IPC  {row}")
    print("  -> BKP (coalescing-hungry) prefers large warps; MU "
          "(divergent) prefers SW+ — the paper's central tension.\n")


def tiny_training_run():
    print("=== Tiny LM training (tinyllama-family smoke config) ===")
    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=20)
    opt = adamw.init(params)
    data = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=64, global_batch=4))

    @jax.jit
    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: model_lib.train_loss(p, cfg, batch), has_aux=True)(params)
        params, opt, _ = adamw.apply(opt_cfg, grads, opt, params)
        return params, opt, loss

    for i in range(20):
        params, opt, loss = step(params, opt, data.batch_at(i))
        if i % 5 == 0 or i == 19:
            print(f"  step {i:3d} loss {float(loss):.4f}")
    print()


if __name__ == "__main__":
    warp_size_study()
    tiny_training_run()
    print("done — see examples/warpsize_study.py for the full suite and "
          "examples/serve_batched.py for the serving path.")
