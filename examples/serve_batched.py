"""End-to-end serving example: batched requests through the slot-based
continuous-batching server (deliverable b: 'serve a small model with
batched requests').

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import sys

sys.path.insert(0, "src")

from repro.launch import serve


if __name__ == "__main__":
    stats = serve.main(["--arch", "tinyllama-1.1b", "--smoke",
                        "--requests", "8", "--slots", "4",
                        "--max-new", "12"])
    print(f"served {stats['requests']} requests in {stats['decode_steps']} "
          f"fused decode steps ({stats['tokens_per_s']:.1f} tok/s on CPU)")
