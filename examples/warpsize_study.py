"""Full warp-size study: every benchmark x machine, the paper's headline
claims, and a dense 4..128 warp-size scaling sweep — all driven through the
cached, process-parallel sweep engine (``repro.core.warpsim.sweep``).

Run:  PYTHONPATH=src python examples/warpsize_study.py

Re-running is near-instant: every grid cell is served from the
content-addressed cache under benchmarks/results/sweep_cache. With
``WARPSIM_SERVICE_URL`` pointing at a running sweep service
(``python -m repro.core.warpsim.service``), the grids are fetched from the
daemon instead — its cache is shared by every client, so nothing is ever
simulated twice across the whole fleet.
"""
import sys
import time

sys.path.insert(0, "src")

from repro.core.warpsim import machines, runner, service
from repro.core.warpsim.sweep import (
    ResultCache, SweepSpec, run_sweep_with_stats,
)

CACHE_DIR = "benchmarks/results/sweep_cache"


def main():
    client = service.from_env()
    cache = None if client is not None else ResultCache(CACHE_DIR)

    def sweep(spec):
        """Grid + per-run stats snapshot, remote or local."""
        if client is not None:
            res = client.sweep(spec)
            return res, client.last_stats
        return run_sweep_with_stats(spec, cache=cache, persist_traces=True)

    if client is not None:
        h = client.healthz()
        print(f"using sweep service at {client.base_url} "
              f"(engine={h['engine']}, model={h['model']})")

    print("running 15 benchmarks x 6 machines (paper Figs. 2-7)...")
    print(f"  {machines.sharing_plan(machines.paper_suite())}")
    for ekey, names in machines.expansion_groups(machines.paper_suite()).items():
        if len(names) > 1:
            print(f"  {'+'.join(names)} share one expansion "
                  f"(warp={ekey[0]}, simd={ekey[1]})")
    spec = SweepSpec(machines=machines.paper_suite())
    t0 = time.time()
    res, stats = sweep(spec)
    print(f"  {len(spec.cells())} cells in {time.time() - t0:.2f}s "
          f"({stats['cache_hits']} cached, {stats['simulated']} simulated, "
          f"{stats['expansion_groups']} aggregations from "
          f"{stats['trace_families']} thread traces)")
    print(f"  trace cache: {stats['trace_cache_hits']} hits / "
          f"{stats['trace_cache_misses']} misses "
          f"({stats['trace_disk_hits']} from disk, "
          f"{stats['traces_shared']} aggregations rode a "
          f"shared trace); expansion LRU: "
          f"{stats['expansion_cache_hits']} hits / "
          f"{stats['expansion_cache_misses']} misses")

    benches = list(next(iter(res.values())))
    print(f"\n{'':6s}" + " ".join(f"{b:>6s}" for b in benches))
    for m in res:
        print(f"{m:6s}" + " ".join(f"{res[m][b].ipc:6.2f}" for b in benches))

    print("\nheadline comparisons (paper Fig. 7 / Secs. 6.2-6.3):")
    s = runner.suite_summary(res)
    paper = {
        "swplus_over_lwplus": 1.11, "swplus_over_ws8": 1.16,
        "swplus_over_ws16": 1.12, "swplus_over_ws32": 1.19,
        "lwplus_over_ws8": 1.05, "lwplus_over_ws16": 1.01,
        "lwplus_over_ws32": 1.07, "lwplus_over_ws64": 1.15,
    }
    for k, v in s.items():
        ref = paper.get(k)
        ref_s = f"(paper {ref:.2f})" if ref else ""
        print(f"  {k:40s} {v:6.3f} {ref_s}")

    print("\ndense warp-size scaling sweep, 4..128 threads/warp:")
    dense = SweepSpec.warp_size_range(4, 128)
    t0 = time.time()
    dres, dstats = sweep(dense)
    print(f"  {len(dense.cells())} cells in {time.time() - t0:.2f}s "
          f"(trace cache: {dstats['trace_cache_hits']}h/"
          f"{dstats['trace_cache_misses']}m, "
          f"{dstats['trace_disk_hits']} from disk)")
    for m, per_bench in dres.items():
        print(f"  {m:6s} geomean IPC {runner.mean_ipc(per_bench):6.3f}")

    runner.save_results(res, "benchmarks/results/warpsim_suite.json")
    print("\nsaved benchmarks/results/warpsim_suite.json")


if __name__ == "__main__":
    main()
