"""Full warp-size study: every benchmark x machine, the paper's headline
claims, and a dense 4..128 warp-size scaling sweep — all driven through
the unified ``repro.core.warpsim.api`` facade.

Run:  PYTHONPATH=src python examples/warpsize_study.py

Which entry point do I use?

* ``api.Session(cache_dir=...).run(api.Study(...))`` — one grid in this
  process, cells cached on disk. The default. Returns a typed
  ``StudyResult``: flat records plus accessors (``per_bench``, ``by``,
  ``summary``, ``bands``) instead of nested dicts.
* ``api.Session.from_env(cache_dir=...)`` — what this script (and figure
  generation) uses: prefers a live sweep daemon named by
  ``WARPSIM_SERVICE_URL`` (``python -m repro.core.warpsim.service``; its
  cache is shared by every client, so nothing is ever simulated twice
  across the whole fleet) and falls back to the in-process session.
  ``WARPSIM_BACKEND=inprocess|service|queue`` forces the choice.
* ``api.Session(backend=api.QueueBackend(url))`` — shard a big grid onto
  the daemon's lease-based work queue and drain it as one of possibly
  many workers (other hosts can run
  ``python -m repro.core.warpsim.work_queue --url ... --job ...``).
* ``sweep.run_sweep`` / ``runner.run_suite`` — the low-level engine and
  its deprecated nested-dict shim; only for code that predates the
  facade.

Engines: ``api.Study(engine=...)`` accepts
``auto|native|fast|fast_nested|event|pallas``. The default ``auto``
resolves to the compiled C core (else the flat numpy loop) and never to
``pallas`` — the device engine is opt-in. With jax installed,
``engine="pallas"`` batches each trace family (all expansion keys x
machine variants of one thread trace) into a single ``jax.jit`` device
launch, bit-identical to every other engine; set ``WARPSIM_PALLAS=0``
to kill it without restarting anything.

Re-running is near-instant: every grid cell is served from the
content-addressed cache under benchmarks/results/sweep_cache.
"""
import sys
import time

sys.path.insert(0, "src")

from repro.core.warpsim import api, machines
from repro.core.warpsim import obs

CACHE_DIR = "benchmarks/results/sweep_cache"


def print_obs_snapshot(session):
    """Where the time went, from the warpsim.obs registry — the same
    store a daemon serves at ``GET /metrics`` — instead of hand-rolled
    counter dicts."""
    print("\nobservability (warpsim.obs registry snapshot):")
    stages = obs.default().registry.snapshot().get("warpsim_stage_seconds",
                                                   {})
    rows = sorted(label[:-len(".count")] for label in stages
                  if label.endswith(".count") and stages[label])
    for label in rows:
        n = int(stages[label + ".count"])
        total = stages[label + ".sum"]
        stage = (label[len('{stage="'):-len('"}')]
                 if label.startswith('{stage="') else label)
        print(f"  {stage:24s} {n:6d} x {1e3 * total / n:8.3f} ms "
              f"= {total:7.3f} s")
    if not rows:
        print("  (no local stages timed", end="")
        if isinstance(session.backend, api.ServiceBackend):
            print(f" — the daemon did the work; scrape "
                  f"{session.backend.url}/metrics for its histograms)")
        else:
            print(")")


def main():
    session = api.Session.from_env(cache_dir=CACHE_DIR, persist_traces=True)
    if isinstance(session.backend, api.ServiceBackend):
        h = session.backend.client().healthz()
        print(f"using sweep service at {session.backend.url} "
              f"(engine={h['engine']}, model={h['model']})")

    print("running 15 benchmarks x 6 machines (paper Figs. 2-7)...")
    print(f"  {machines.sharing_plan(machines.paper_suite())}")
    for ekey, names in machines.expansion_groups(machines.paper_suite()).items():
        if len(names) > 1:
            print(f"  {'+'.join(names)} share one expansion "
                  f"(warp={ekey[0]}, simd={ekey[1]})")
    study = api.Study(machines=machines.paper_suite())
    t0 = time.time()
    res = session.run(study)
    stats = res.stats
    print(f"  {len(res)} cells in {time.time() - t0:.2f}s "
          f"({stats['cache_hits']} cached, {stats['simulated']} simulated, "
          f"{stats['expansion_groups']} aggregations from "
          f"{stats['trace_families']} thread traces) "
          f"via the {res.backend} backend")
    print(f"  trace cache: {stats['trace_cache_hits']} hits / "
          f"{stats['trace_cache_misses']} misses "
          f"({stats['trace_disk_hits']} from disk, "
          f"{stats['traces_shared']} aggregations rode a "
          f"shared trace); expansion LRU: "
          f"{stats['expansion_cache_hits']} hits / "
          f"{stats['expansion_cache_misses']} misses")

    print(f"\n{'':6s}" + " ".join(f"{b:>6s}" for b in res.benches))
    for m in res.machines:
        per_b = res.per_bench(m)
        print(f"{m:6s}" + " ".join(f"{per_b[b].ipc:6.2f}"
                                   for b in res.benches))

    print("\nheadline comparisons (paper Fig. 7 / Secs. 6.2-6.3):")
    s = res.summary()
    paper = {
        "swplus_over_lwplus": 1.11, "swplus_over_ws8": 1.16,
        "swplus_over_ws16": 1.12, "swplus_over_ws32": 1.19,
        "lwplus_over_ws8": 1.05, "lwplus_over_ws16": 1.01,
        "lwplus_over_ws32": 1.07, "lwplus_over_ws64": 1.15,
    }
    for k, v in s.items():
        ref = paper.get(k)
        ref_s = f"(paper {ref:.2f})" if ref else ""
        print(f"  {k:40s} {v:6.3f} {ref_s}")

    print("\ndense warp-size scaling sweep, 4..128 threads/warp:")
    dense = api.Study.warp_size_range(4, 128)
    t0 = time.time()
    dres = session.run(dense)
    dstats = dres.stats
    print(f"  {len(dres)} cells in {time.time() - t0:.2f}s "
          f"(trace cache: {dstats['trace_cache_hits']}h/"
          f"{dstats['trace_cache_misses']}m, "
          f"{dstats['trace_disk_hits']} from disk)")
    from repro.core.warpsim import runner
    for m in dres.machines:
        print(f"  {m:6s} geomean IPC "
              f"{runner.mean_ipc(dres.per_bench(m)):6.3f}")

    print_obs_snapshot(session)

    runner.save_results(res.legacy_grid(),
                        "benchmarks/results/warpsim_suite.json")
    print("\nsaved benchmarks/results/warpsim_suite.json")


if __name__ == "__main__":
    main()
