"""Full warp-size study: every benchmark x machine, the paper's headline
claims, and the TPU-side analogy (MoE dispatch strategies).

Run:  PYTHONPATH=src python examples/warpsize_study.py
"""
import json
import sys

sys.path.insert(0, "src")

from repro.core.warpsim import machines, runner


def main():
    print("running 15 benchmarks x 6 machines (paper Figs. 2-7)...")
    res = runner.run_suite(machines.paper_suite())
    benches = list(next(iter(res.values())))
    print(f"\n{'':6s}" + " ".join(f"{b:>6s}" for b in benches))
    for m in res:
        print(f"{m:6s}" + " ".join(f"{res[m][b].ipc:6.2f}" for b in benches))
    print("\nheadline comparisons (paper Fig. 7 / Secs. 6.2-6.3):")
    s = runner.suite_summary(res)
    paper = {
        "swplus_over_lwplus": 1.11, "swplus_over_ws8": 1.16,
        "swplus_over_ws16": 1.12, "swplus_over_ws32": 1.19,
        "lwplus_over_ws8": 1.05, "lwplus_over_ws16": 1.01,
        "lwplus_over_ws32": 1.07, "lwplus_over_ws64": 1.15,
    }
    for k, v in s.items():
        ref = paper.get(k)
        ref_s = f"(paper {ref:.2f})" if ref else ""
        print(f"  {k:40s} {v:6.3f} {ref_s}")
    runner.save_results(res, "benchmarks/results/warpsim_suite.json")
    print("\nsaved benchmarks/results/warpsim_suite.json")


if __name__ == "__main__":
    main()
