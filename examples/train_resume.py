"""Fault-tolerance drill: train, die at step 12, restart, verify the loss
curve continues exactly where it left off (deterministic restorable data
+ atomic checkpoints).

Run:  PYTHONPATH=src python examples/train_resume.py
"""
import shutil
import sys
import tempfile

sys.path.insert(0, "src")

from repro.launch import train
from repro.runtime.fault import SimulatedFailure


def main():
    d = tempfile.mkdtemp(prefix="repro_resume_")
    args = ["--arch", "tinyllama-1.1b", "--smoke", "--steps", "24",
            "--batch", "4", "--seq-len", "64", "--ckpt-dir", d,
            "--ckpt-every", "6", "--log-every", "6"]
    print("run 1 (will be killed at step 12):")
    try:
        train.main(args + ["--fail-at", "12"])
    except SimulatedFailure as e:
        print(f"  !! {e}")
    print("run 2 (restarts from the last checkpoint):")
    out = train.main(args)
    print(f"resumed and finished: final loss {out['last_loss']:.4f}")
    shutil.rmtree(d)


if __name__ == "__main__":
    main()
